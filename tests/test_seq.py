"""Sequential algorithm tests: numerics vs BLAS reference + communication
counters vs the paper's cost formulas (Algs 4–6, §VII)."""
import numpy as np
import pytest

pytest.importorskip("hypothesis",
                    reason="property-based tests need the hypothesis "
                           "dev dependency (requirements-dev.txt)")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.lower_bounds import (seq_algorithm_reads,
                                     sequential_reads_lower_bound)
from repro.core.seq import seq_symm, seq_syr2k, seq_syrk
from repro.core.triangle import affine_partition


def _rand(shape, seed):
    return np.random.default_rng(seed).standard_normal(shape)


@pytest.mark.parametrize("n1,n2,M", [(16, 8, 40), (49, 30, 200),
                                     (64, 64, 300), (128, 32, 500)])
def test_syrk_numerics(n1, n2, M):
    A = _rand((n1, n2), n1)
    r = seq_syrk(A, M=M)
    np.testing.assert_allclose(np.tril(r.C), np.tril(A @ A.T), atol=1e-9)
    # upper strict triangle untouched (only unique entries computed)
    assert (np.triu(r.C, 1) == 0).all()


@pytest.mark.parametrize("n1,n2,M", [(16, 8, 40), (49, 30, 200), (64, 64, 300)])
def test_syr2k_numerics(n1, n2, M):
    A, B = _rand((n1, n2), 1), _rand((n1, n2), 2)
    r = seq_syr2k(A, B, M=M)
    np.testing.assert_allclose(np.tril(r.C), np.tril(A @ B.T + B @ A.T),
                               atol=1e-9)


@pytest.mark.parametrize("n1,n2,M", [(16, 8, 40), (49, 30, 200), (64, 16, 300)])
def test_symm_numerics(n1, n2, M):
    S = _rand((n1, n1), 3)
    S = np.tril(S) + np.tril(S, -1).T
    B = _rand((n1, n2), 4)
    r = seq_symm(S, B, M=M)
    np.testing.assert_allclose(r.C, S @ B, atol=1e-9)


def test_accumulate_into_existing_C():
    A = _rand((32, 16), 0)
    C0 = _rand((32, 32), 1)
    r = seq_syrk(A, C=C0, M=100)
    np.testing.assert_allclose(np.tril(r.C), np.tril(C0 + A @ A.T), atol=1e-9)


def test_explicit_partition():
    p = affine_partition(4)  # n = 16
    A = _rand((16, 8), 0)
    r = seq_syrk(A, M=10**6, partition=p)
    np.testing.assert_allclose(np.tril(r.C), np.tril(A @ A.T), atol=1e-9)
    assert r.K == 20


@settings(max_examples=15, deadline=None)
@given(n1=st.integers(8, 80), n2=st.integers(1, 40), logM=st.integers(5, 9))
def test_syrk_property(n1, n2, logM):
    A = _rand((n1, n2), n1 * 1000 + n2)
    r = seq_syrk(A, M=1 << logM)
    np.testing.assert_allclose(np.tril(r.C), np.tril(A @ A.T), atol=1e-8)
    assert r.peak_resident <= (1 << logM)


def test_reads_track_paper_cost_formula():
    """Counters within ~25% of the paper's leading-order cost (§VII-B2) in
    the paper's regime n1 >> 2M (constructive-Steiner gap documented in
    DESIGN.md)."""
    n1, n2, M = 1024, 64, 128
    A = _rand((n1, n2), 0)
    r = seq_syrk(A, M=M)
    alg = seq_algorithm_reads(n1, n2, M, 1)
    assert r.reads <= 1.25 * alg
    lb = sequential_reads_lower_bound(n1, n2, M, 1)
    assert r.reads >= lb  # lower bound must hold


def test_writes_syrk_exact():
    # SYRK writes each unique entry exactly once (§VII-D)
    n1, n2 = 49, 16
    A = _rand((n1, n2), 0)
    r = seq_syrk(A, M=200)
    assert r.writes <= n1 * (n1 + 1) // 2
    assert r.writes >= n1 * (n1 - 1) // 2


def test_symm_write_volume():
    # SYMM writes each C row once per triangle block containing the row
    # index: total = n1*n2*(n_hat-1)/(r-1) approx (§VII-D)
    n1, n2, M = 256, 32, 300
    S = _rand((n1, n1), 0)
    S = np.tril(S) + np.tril(S, -1).T
    B = _rand((n1, n2), 1)
    r = seq_symm(S, B, M=M)
    assert r.writes > n1 * n2  # strictly more than one pass
    # ... but bounded by reads (writes ~ half of panel reads)
    assert r.writes < r.reads
