"""Serving driver + multi-tenant packed Gram/whitening cache.

Covers the continuous-batching mechanics (bucket selection, slot
refill on EOS/max-new, the AOT-precompiled prefill ladder) and the
serving-cache contract (tenant isolation, async-refresh determinism,
warm-start-from-packed-checkpoint parity).
"""
import argparse
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(
    os.path.dirname(os.path.abspath(__file__))), "src"))

from repro.configs import get_smoke_config
from repro.launch.serve import Request, Server, serve, synthetic_requests
from repro.launch.serving_cache import ServingGramCache
from repro.models.model import init_params


@pytest.fixture(scope="module")
def smoke():
    cfg = get_smoke_config("stablelm-1.6b")
    params = init_params(cfg, jax.random.key(0))
    return cfg, params


def _server(smoke, **kw):
    cfg, params = smoke
    kw.setdefault("slots", 2)
    kw.setdefault("s_max", 32)
    kw.setdefault("max_new", 4)
    kw.setdefault("eos_id", -1)
    return Server(cfg, params, **kw)


def _req(rid, length, tenant="default", vocab=512, seed=0):
    rng = np.random.default_rng(seed + rid)
    return Request(rid=rid, tenant=tenant, prompt=rng.integers(
        1, vocab, size=length).astype(np.int32))


# -------------------------------------------------------------------------
# batching mechanics
# -------------------------------------------------------------------------
def test_bucket_selection(smoke):
    srv = _server(smoke, precompile=False)
    assert srv._bucket(1) == 16
    assert srv._bucket(16) == 16
    assert srv._bucket(17) == 32
    assert srv._bucket(100) == 32          # clamped to s_max
    assert srv.bucket_ladder() == [16, 32]


def test_prefill_ladder_precompiled_no_midserve_compiles(smoke):
    srv = _server(smoke)
    assert srv.prefill_compiles == len(srv.bucket_ladder())
    before = srv.prefill_compiles
    for rid, L in enumerate((5, 20, 31, 8)):   # both buckets, repeats
        slot = srv.free_slot()
        while slot is None:
            srv.step()
            slot = srv.free_slot()
        srv.admit(_req(rid, L), slot)
    assert srv.prefill_compiles == before      # ladder covered them all
    assert set(srv._prefill) <= set(srv.bucket_ladder())


def test_slot_refill_on_max_new(smoke):
    args = argparse.Namespace(
        arch="stablelm-1.6b", smoke=True, requests=5, slots=2, s_max=32,
        max_new=3, prompt_lo=4, prompt_hi=20, tenants=1, whiten="off",
        refresh_stride=1, warm_start=None, save_cache=None, no_eos=True,
        seed=0)
    out = serve(args)
    # 5 requests through 2 slots: every one finishes at max_new tokens
    assert out["completed"] == 5
    assert out["total_new_tokens"] == 5 * 3
    assert out["prefill_compiles"] == len(out["bucket_ladder"])


def test_slot_refill_on_eos(smoke):
    srv = _server(smoke, max_new=64)
    r1 = _req(0, 6)
    srv.admit(r1, 0)
    srv.step()                      # deterministic argmax decode
    eos = r1.generated[-1]
    srv2 = _server(smoke, max_new=64, eos_id=eos)
    r2 = _req(0, 6)                 # same prompt -> same first tokens
    srv2.admit(r2, 0)
    srv2.step()
    assert r2.generated[-1] == eos
    assert r2.done_t is not None and srv2.live[0] is None  # slot freed


# -------------------------------------------------------------------------
# multi-tenant cache keying / isolation
# -------------------------------------------------------------------------
def test_tenant_isolation():
    cache = ServingGramCache(refresh_stride=1, synchronous=True)
    x = jax.random.normal(jax.random.key(0), (16, 24))
    cache.update("tA", "arch", "final", x)
    cache.update("tB", "arch", "final", 2.0 * x)
    wa = cache.factor("tA", "arch", "final")
    wb = cache.factor("tB", "arch", "final")
    # disjoint EMA state by construction, and the factors differ
    assert set(cache._monitors) == {("tA", "arch"), ("tB", "arch")}
    assert not np.allclose(np.asarray(wa), np.asarray(wb))
    # tenant A's Gram state is untouched by tenant B's updates
    ga = cache._monitors[("tA", "arch")]._state["final"]
    cache.update("tB", "arch", "final", 3.0 * x)
    np.testing.assert_array_equal(
        np.asarray(ga),
        np.asarray(cache._monitors[("tA", "arch")]._state["final"]))


def test_refresh_stride_and_coalescing():
    cache = ServingGramCache(refresh_stride=3, synchronous=True)
    x = jax.random.normal(jax.random.key(1), (8, 16))
    for _ in range(2):
        cache.update("t", "a", "l", x)
    assert cache.factor("t", "a", "l") is None     # below stride: cold
    cache.update("t", "a", "l", x)                 # 3rd update refreshes
    assert cache.factor("t", "a", "l") is not None
    assert cache.stats["refreshes"] == 1


# -------------------------------------------------------------------------
# async-refresh determinism
# -------------------------------------------------------------------------
def _generate(smoke, whiten, gram_cache=None):
    srv = _server(smoke, whiten=whiten, gram_cache=gram_cache,
                  max_new=4, slots=2)
    queue = [_req(i, 5 + 3 * i, tenant=f"t{i % 2}") for i in range(4)]
    reqs = list(queue)
    while queue or any(r is not None for r in srv.live):
        while queue:
            s = srv.free_slot()
            if s is None:
                break
            srv.admit(queue.pop(0), s)
        srv.step()
    if srv.gram_cache is not None:
        srv.gram_cache.drain()
    return [tuple(r.generated) for r in reqs]


def test_decode_independent_of_refresh_timing(smoke):
    """Generated tokens are identical with the cache off, with a
    synchronous (deterministic-completion) cache, and with the async
    executor racing the decode loop — factors are per-request side
    outputs, never decode inputs."""
    base = _generate(smoke, "off")
    sync_cache = _generate(smoke, "cache", ServingGramCache(
        refresh_stride=1, synchronous=True))
    async_cache = _generate(smoke, "cache", ServingGramCache(
        refresh_stride=1))
    assert base == sync_cache == async_cache


def test_cache_embeddings_whiten(smoke):
    """After enough updates the cached factor actually whitens: the
    served embedding is W·pooled with W ≈ (G+εI)^{-1/2}."""
    cfg, _ = smoke
    cache = ServingGramCache(refresh_stride=1, synchronous=True)
    srv = _server(smoke, whiten="cache", gram_cache=cache, slots=1)
    for i in range(3):
        srv.admit(_req(i, 12, tenant="t0"), 0)
        srv.live[0] = None                    # recycle the slot
    w = cache.factor("t0", cfg.name, "final")
    assert w is not None and w.shape == (cfg.d_model, cfg.d_model)
    assert np.all(np.isfinite(np.asarray(w)))
    assert srv.live[0] is None


# -------------------------------------------------------------------------
# warm start from packed checkpoint
# -------------------------------------------------------------------------
def test_warm_start_parity(tmp_path):
    """save -> warm_start round-trips the bf16 packed EMA bit-exactly,
    so the warm factor equals the live one; warm_start discovers the
    keying from the manifest alone."""
    cache = ServingGramCache(refresh_stride=1, synchronous=True)
    k = jax.random.key(2)
    for i, (tenant, layer) in enumerate(
            [("tA", "final"), ("tB", "final"), ("tA", "mid")]):
        cache.update(tenant, "arch", layer,
                     jax.random.normal(jax.random.fold_in(k, i), (16, 24)))
    cache.save(str(tmp_path), step=7)

    warm = ServingGramCache(refresh_stride=1, synchronous=True)
    assert warm.warm_start(str(tmp_path)) == 3
    assert warm.stats["warm_loaded"] == 3
    for tenant, layer in [("tA", "final"), ("tB", "final"), ("tA", "mid")]:
        w_live = cache.factor(tenant, "arch", layer)
        w_warm = warm.factor(tenant, "arch", layer)
        assert w_warm is not None
        np.testing.assert_array_equal(np.asarray(w_live),
                                      np.asarray(w_warm))


def test_serve_end_to_end_cache_report(smoke, tmp_path):
    args = argparse.Namespace(
        arch="stablelm-1.6b", smoke=True, requests=6, slots=2, s_max=32,
        max_new=3, prompt_lo=4, prompt_hi=20, tenants=2, whiten="cache",
        refresh_stride=2, warm_start=None,
        save_cache=str(tmp_path / "ck"), no_eos=True, seed=0)
    out = serve(args)
    assert out["completed"] == 6
    assert out["cache"]["updates"] == 6
    assert out["cache"]["keys"] == 2          # one per tenant
    assert out["p99_latency_s"] >= out["p50_latency_s"]
    # the saved cache warm-starts a fresh one
    warm = ServingGramCache(synchronous=True)
    assert warm.warm_start(str(tmp_path / "ck")) == 2


# -------------------------------------------------------------------------
# graceful degradation under refresh chaos (PR 10)
# -------------------------------------------------------------------------
import time  # noqa: E402

from repro.distributed import faults  # noqa: E402


@pytest.fixture()
def feats():
    return jax.random.normal(jax.random.key(3), (16, 32))


def test_refresh_failure_observed_and_retried(feats):
    """A transient refresh fault heals inside with_retries (zero
    counted failures); a persistent one is counted by the done-callback
    and the last-good factor keeps serving — nothing raises into the
    admit path."""
    cache = ServingGramCache(refresh_stride=1, refresh_retries=2,
                             refresh_backoff=0.01, breaker_threshold=3)
    with faults.inject(faults.FaultSpec(site="serve:refresh",
                                        kind="error", times=1)):
        cache.update("t", "a", "l", feats)
        cache.drain()
    assert cache.factor("t", "a", "l") is not None
    assert cache.snapshot_stats()["failed_refreshes"] == 0  # healed
    w_good = np.asarray(cache.factor("t", "a", "l"))
    with faults.inject(faults.FaultSpec(site="serve:refresh",
                                        kind="error", times=0)):
        cache.update("t", "a", "l", feats)
        cache.drain()
    st = cache.snapshot_stats()
    assert st["failed_refreshes"] == 1 and st["pending"] == 0
    np.testing.assert_array_equal(
        np.asarray(cache.factor("t", "a", "l")), w_good)


def test_breaker_holds_last_good_then_half_open_recovers(feats):
    """K consecutive refresh failures open the breaker: the key is
    marked stale, further refreshes are skipped, the last-good factor
    is served bitwise; after the cooldown one half-open probe closes
    it again on success."""
    cache = ServingGramCache(refresh_stride=1, synchronous=True,
                             refresh_retries=0, breaker_threshold=2,
                             breaker_cooldown_s=0.2)
    cache.update("t", "a", "l", feats)
    w_good = np.asarray(cache.factor("t", "a", "l"))
    with faults.inject(faults.FaultSpec(site="serve:refresh",
                                        kind="error", times=0)):
        for _ in range(3):                 # 2 failures open it; 3rd is
            cache.update("t", "a", "l", feats)   # skipped by the breaker
        st = cache.snapshot_stats()
        assert st["failed_refreshes"] == 2
        assert st["stale"] == ["t/a/l"]
        np.testing.assert_array_equal(
            np.asarray(cache.factor("t", "a", "l")), w_good)
    time.sleep(0.25)
    cache.update("t", "a", "l", feats)     # half-open probe succeeds
    assert cache.snapshot_stats()["stale"] == []


def test_ns_nan_guard_falls_back_to_eigh_oracle(feats):
    """A Gram snapshot that sends Newton–Schulz to NaN/Inf degrades to
    the exact eigh oracle: the served factor is finite and equals the
    oracle's answer for the same packed words."""
    cache = ServingGramCache(refresh_stride=1, synchronous=True)
    cache.update("t", "a", "l", feats)
    mon = cache.monitor("t", "a")
    bad = np.array(mon._state["l"], dtype=np.float32)
    bad[0] = -1e30                         # wildly indefinite
    mon._state["l"] = jnp.asarray(bad).astype(mon._state["l"].dtype)
    assert cache._schedule_refresh(("t", "a", "l"))
    w = cache.factor("t", "a", "l")
    assert w is not None and bool(jnp.all(jnp.isfinite(w)))
    assert cache.stats["ns_fallbacks"] >= 1
    oracle = cache._oracle_fn(16)(mon._state["l"])
    np.testing.assert_array_equal(np.asarray(w), np.asarray(oracle))


def test_illconditioned_bf16_gram_stays_finite():
    """cond >= 1e8 features, bf16-quantized EMA storage: whatever path
    the refresh takes (NS or the guard's eigh fallback), the served
    factor is finite."""
    d = 16
    u = np.linalg.qr(np.random.default_rng(5)
                     .standard_normal((d, d)))[0].astype(np.float32)
    scales = np.logspace(0, -8, d).astype(np.float32)   # cond 1e16 Gram
    x = (u * scales) @ np.random.default_rng(6) \
        .standard_normal((d, 64)).astype(np.float32)
    cache = ServingGramCache(refresh_stride=1, synchronous=True)
    cache.update("t", "a", "l", jnp.asarray(x))
    w = cache.factor("t", "a", "l")
    assert w is not None and bool(jnp.all(jnp.isfinite(w)))


def test_decode_unchanged_under_refresh_chaos(smoke):
    """Factors are per-request side outputs, never decode inputs — so
    even persistent refresh failures leave generated tokens
    bit-identical to the fault-free run."""
    base = _generate(smoke, "cache", ServingGramCache(
        refresh_stride=1, refresh_retries=0, breaker_threshold=2))
    with faults.inject(faults.FaultSpec(site="serve:refresh",
                                        kind="error", times=0)):
        chaotic = _generate(smoke, "cache", ServingGramCache(
            refresh_stride=1, refresh_retries=0, breaker_threshold=2))
    assert chaotic == base


# -------------------------------------------------------------------------
# TTL eviction of dormant tenants (PR 10)
# -------------------------------------------------------------------------
def test_ttl_eviction_and_bitexact_warm_readmit(tmp_path, feats):
    """A dormant tenant is swept after max_idle_s; it re-admits cleanly
    (cold again, fresh EMA) and a warm start from its checkpoint
    restores the packed EMA bit-exactly."""
    cache = ServingGramCache(refresh_stride=1, synchronous=True,
                             max_idle_s=0.05)
    cache.update("tA", "a", "l", feats)
    ref = np.array(cache.monitor("tA", "a")._state["l"])
    cache.save(str(tmp_path), step=0)
    time.sleep(0.1)
    cache.update("tB", "a", "l", feats)    # the sweep runs here
    assert cache.stats["evicted"] == 1
    assert ("tA", "a") not in cache._monitors
    assert cache.factor("tA", "a", "l") is None        # cold again
    cache.update("tA", "a", "l", feats)                # clean re-admit
    assert cache.factor("tA", "a", "l") is not None

    warm = ServingGramCache(refresh_stride=1, synchronous=True)
    assert warm.warm_start(str(tmp_path), refresh=False) == 1
    got = np.array(warm.monitor("tA", "a")._state["l"])
    assert got.tobytes() == ref.tobytes()


def test_explicit_evict(feats):
    cache = ServingGramCache(refresh_stride=1, synchronous=True)
    cache.update("t", "a", "l0", feats)
    cache.update("t", "a", "l1", feats)
    assert cache.evict("t", "a") == 2
    assert ("t", "a") not in cache._monitors
    assert cache.stats["evicted"] == 2
