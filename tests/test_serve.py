"""Serving driver + multi-tenant packed Gram/whitening cache.

Covers the continuous-batching mechanics (bucket selection, slot
refill on EOS/max-new, the AOT-precompiled prefill ladder) and the
serving-cache contract (tenant isolation, async-refresh determinism,
warm-start-from-packed-checkpoint parity).
"""
import argparse
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(
    os.path.dirname(os.path.abspath(__file__))), "src"))

from repro.configs import get_smoke_config
from repro.launch.serve import Request, Server, serve, synthetic_requests
from repro.launch.serving_cache import ServingGramCache
from repro.models.model import init_params


@pytest.fixture(scope="module")
def smoke():
    cfg = get_smoke_config("stablelm-1.6b")
    params = init_params(cfg, jax.random.key(0))
    return cfg, params


def _server(smoke, **kw):
    cfg, params = smoke
    kw.setdefault("slots", 2)
    kw.setdefault("s_max", 32)
    kw.setdefault("max_new", 4)
    kw.setdefault("eos_id", -1)
    return Server(cfg, params, **kw)


def _req(rid, length, tenant="default", vocab=512, seed=0):
    rng = np.random.default_rng(seed + rid)
    return Request(rid=rid, tenant=tenant, prompt=rng.integers(
        1, vocab, size=length).astype(np.int32))


# -------------------------------------------------------------------------
# batching mechanics
# -------------------------------------------------------------------------
def test_bucket_selection(smoke):
    srv = _server(smoke, precompile=False)
    assert srv._bucket(1) == 16
    assert srv._bucket(16) == 16
    assert srv._bucket(17) == 32
    assert srv._bucket(100) == 32          # clamped to s_max
    assert srv.bucket_ladder() == [16, 32]


def test_prefill_ladder_precompiled_no_midserve_compiles(smoke):
    srv = _server(smoke)
    assert srv.prefill_compiles == len(srv.bucket_ladder())
    before = srv.prefill_compiles
    for rid, L in enumerate((5, 20, 31, 8)):   # both buckets, repeats
        slot = srv.free_slot()
        while slot is None:
            srv.step()
            slot = srv.free_slot()
        srv.admit(_req(rid, L), slot)
    assert srv.prefill_compiles == before      # ladder covered them all
    assert set(srv._prefill) <= set(srv.bucket_ladder())


def test_slot_refill_on_max_new(smoke):
    args = argparse.Namespace(
        arch="stablelm-1.6b", smoke=True, requests=5, slots=2, s_max=32,
        max_new=3, prompt_lo=4, prompt_hi=20, tenants=1, whiten="off",
        refresh_stride=1, warm_start=None, save_cache=None, no_eos=True,
        seed=0)
    out = serve(args)
    # 5 requests through 2 slots: every one finishes at max_new tokens
    assert out["completed"] == 5
    assert out["total_new_tokens"] == 5 * 3
    assert out["prefill_compiles"] == len(out["bucket_ladder"])


def test_slot_refill_on_eos(smoke):
    srv = _server(smoke, max_new=64)
    r1 = _req(0, 6)
    srv.admit(r1, 0)
    srv.step()                      # deterministic argmax decode
    eos = r1.generated[-1]
    srv2 = _server(smoke, max_new=64, eos_id=eos)
    r2 = _req(0, 6)                 # same prompt -> same first tokens
    srv2.admit(r2, 0)
    srv2.step()
    assert r2.generated[-1] == eos
    assert r2.done_t is not None and srv2.live[0] is None  # slot freed


# -------------------------------------------------------------------------
# multi-tenant cache keying / isolation
# -------------------------------------------------------------------------
def test_tenant_isolation():
    cache = ServingGramCache(refresh_stride=1, synchronous=True)
    x = jax.random.normal(jax.random.key(0), (16, 24))
    cache.update("tA", "arch", "final", x)
    cache.update("tB", "arch", "final", 2.0 * x)
    wa = cache.factor("tA", "arch", "final")
    wb = cache.factor("tB", "arch", "final")
    # disjoint EMA state by construction, and the factors differ
    assert set(cache._monitors) == {("tA", "arch"), ("tB", "arch")}
    assert not np.allclose(np.asarray(wa), np.asarray(wb))
    # tenant A's Gram state is untouched by tenant B's updates
    ga = cache._monitors[("tA", "arch")]._state["final"]
    cache.update("tB", "arch", "final", 3.0 * x)
    np.testing.assert_array_equal(
        np.asarray(ga),
        np.asarray(cache._monitors[("tA", "arch")]._state["final"]))


def test_refresh_stride_and_coalescing():
    cache = ServingGramCache(refresh_stride=3, synchronous=True)
    x = jax.random.normal(jax.random.key(1), (8, 16))
    for _ in range(2):
        cache.update("t", "a", "l", x)
    assert cache.factor("t", "a", "l") is None     # below stride: cold
    cache.update("t", "a", "l", x)                 # 3rd update refreshes
    assert cache.factor("t", "a", "l") is not None
    assert cache.stats["refreshes"] == 1


# -------------------------------------------------------------------------
# async-refresh determinism
# -------------------------------------------------------------------------
def _generate(smoke, whiten, gram_cache=None):
    srv = _server(smoke, whiten=whiten, gram_cache=gram_cache,
                  max_new=4, slots=2)
    queue = [_req(i, 5 + 3 * i, tenant=f"t{i % 2}") for i in range(4)]
    reqs = list(queue)
    while queue or any(r is not None for r in srv.live):
        while queue:
            s = srv.free_slot()
            if s is None:
                break
            srv.admit(queue.pop(0), s)
        srv.step()
    if srv.gram_cache is not None:
        srv.gram_cache.drain()
    return [tuple(r.generated) for r in reqs]


def test_decode_independent_of_refresh_timing(smoke):
    """Generated tokens are identical with the cache off, with a
    synchronous (deterministic-completion) cache, and with the async
    executor racing the decode loop — factors are per-request side
    outputs, never decode inputs."""
    base = _generate(smoke, "off")
    sync_cache = _generate(smoke, "cache", ServingGramCache(
        refresh_stride=1, synchronous=True))
    async_cache = _generate(smoke, "cache", ServingGramCache(
        refresh_stride=1))
    assert base == sync_cache == async_cache


def test_cache_embeddings_whiten(smoke):
    """After enough updates the cached factor actually whitens: the
    served embedding is W·pooled with W ≈ (G+εI)^{-1/2}."""
    cfg, _ = smoke
    cache = ServingGramCache(refresh_stride=1, synchronous=True)
    srv = _server(smoke, whiten="cache", gram_cache=cache, slots=1)
    for i in range(3):
        srv.admit(_req(i, 12, tenant="t0"), 0)
        srv.live[0] = None                    # recycle the slot
    w = cache.factor("t0", cfg.name, "final")
    assert w is not None and w.shape == (cfg.d_model, cfg.d_model)
    assert np.all(np.isfinite(np.asarray(w)))
    assert srv.live[0] is None


# -------------------------------------------------------------------------
# warm start from packed checkpoint
# -------------------------------------------------------------------------
def test_warm_start_parity(tmp_path):
    """save -> warm_start round-trips the bf16 packed EMA bit-exactly,
    so the warm factor equals the live one; warm_start discovers the
    keying from the manifest alone."""
    cache = ServingGramCache(refresh_stride=1, synchronous=True)
    k = jax.random.key(2)
    for i, (tenant, layer) in enumerate(
            [("tA", "final"), ("tB", "final"), ("tA", "mid")]):
        cache.update(tenant, "arch", layer,
                     jax.random.normal(jax.random.fold_in(k, i), (16, 24)))
    cache.save(str(tmp_path), step=7)

    warm = ServingGramCache(refresh_stride=1, synchronous=True)
    assert warm.warm_start(str(tmp_path)) == 3
    assert warm.stats["warm_loaded"] == 3
    for tenant, layer in [("tA", "final"), ("tB", "final"), ("tA", "mid")]:
        w_live = cache.factor(tenant, "arch", layer)
        w_warm = warm.factor(tenant, "arch", layer)
        assert w_warm is not None
        np.testing.assert_array_equal(np.asarray(w_live),
                                      np.asarray(w_warm))


def test_serve_end_to_end_cache_report(smoke, tmp_path):
    args = argparse.Namespace(
        arch="stablelm-1.6b", smoke=True, requests=6, slots=2, s_max=32,
        max_new=3, prompt_lo=4, prompt_hi=20, tenants=2, whiten="cache",
        refresh_stride=2, warm_start=None,
        save_cache=str(tmp_path / "ck"), no_eos=True, seed=0)
    out = serve(args)
    assert out["completed"] == 6
    assert out["cache"]["updates"] == 6
    assert out["cache"]["keys"] == 2          # one per tenant
    assert out["p99_latency_s"] >= out["p50_latency_s"]
    # the saved cache warm-starts a fresh one
    warm = ServingGramCache(synchronous=True)
    assert warm.warm_start(str(tmp_path / "ck")) == 2
