"""Pallas sLSTM kernel vs the models/ssm sequential oracle
(interpret mode; shape/dtype sweep per the kernel test policy)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.slstm import hbm_traffic_bytes, slstm_scan
from repro.models import ssm


def _gates(key, b, s, d, scale=2.5):
    ks = jax.random.split(key, 4)
    return [jax.random.normal(ks[i], (b, s, d), jnp.float32)
            * (scale if i in (1, 2) else 1.0) for i in range(4)]


@pytest.mark.parametrize("b,s,d,bd", [
    (1, 64, 128, 128),
    (2, 128, 256, 128),
    (1, 96, 64, 64),       # s not a power of two
])
def test_kernel_matches_seq_oracle(b, s, d, bd):
    z, ig, fg, og = _gates(jax.random.key(0), b, s, d)
    st = {"c": jnp.zeros((b, d)), "n": jnp.ones((b, d)),
          "m": jnp.zeros((b, d))}
    y_ref, st_ref = ssm._slstm_seq(z, ig, fg, og, st)
    y, c1, n1, m1 = slstm_scan(z, ig, fg, og, st["c"], st["n"], st["m"],
                               bd=bd, interpret=True)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(np.asarray(c1), np.asarray(st_ref["c"]),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(n1), np.asarray(st_ref["n"]),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(m1), np.asarray(st_ref["m"]),
                               rtol=2e-4, atol=2e-4)


def test_kernel_warm_state():
    b, s, d = 1, 64, 64
    z, ig, fg, og = _gates(jax.random.key(1), b, s, d)
    st = {"c": jnp.full((b, d), 0.5), "n": jnp.full((b, d), 1.2),
          "m": jnp.full((b, d), 0.3)}
    y_ref, _ = ssm._slstm_seq(z, ig, fg, og, st)
    y, *_ = slstm_scan(z, ig, fg, og, st["c"], st["n"], st["m"],
                       bd=64, interpret=True)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=2e-5, atol=2e-5)


def test_traffic_model_saving_grows_with_s():
    t4k = hbm_traffic_bytes(16, 4096, 1024)
    t32k = hbm_traffic_bytes(2, 32768, 1024)
    assert t4k["saving"] > 10
    assert t32k["saving"] > t4k["saving"]
