"""Chunkwise/parallel recurrent mixers vs their sequential oracles.

The §Perf hillclimb replaced S-trip time scans with chunkwise (mLSTM),
associative-scan (sLSTM), and chunked-associative (Mamba) forms.  These
must be numerically equivalent — same stabilizers, fp reassociation
only."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import ssm
from repro.models.common import ArchConfig


def _mk_qkvg(key, b, s, h, hd, gate_scale=3.0):
    ks = jax.random.split(key, 5)
    q = jax.random.normal(ks[0], (b, s, h, hd), jnp.float32)
    k = jax.random.normal(ks[1], (b, s, h, hd), jnp.float32)
    v = jax.random.normal(ks[2], (b, s, h, hd), jnp.float32)
    ig = gate_scale * jax.random.normal(ks[3], (b, s, h), jnp.float32)
    fg = gate_scale * jax.random.normal(ks[4], (b, s, h), jnp.float32)
    return q, k, v, ig, fg


@pytest.mark.parametrize("seed", [0, 1, 2])
@pytest.mark.parametrize("s", [256, 512])
def test_mlstm_chunkwise_matches_seq(seed, s):
    b, h, hd = 2, 3, 16
    q, k, v, ig, fg = _mk_qkvg(jax.random.key(seed), b, s, h, hd)
    st = {"C": jnp.zeros((b, h, hd, hd)), "n": jnp.zeros((b, h, hd)),
          "m": jnp.full((b, h), -1e30)}
    y_ref, st_ref = ssm._mlstm_seq(q, k, v, ig, fg, st)
    y_chk, st_chk = ssm._mlstm_chunkwise(q, k, v, ig, fg, st, chunk=128)
    np.testing.assert_allclose(np.asarray(y_chk), np.asarray(y_ref),
                               rtol=2e-4, atol=2e-4)
    for key_ in ("C", "n", "m"):
        np.testing.assert_allclose(np.asarray(st_chk[key_]),
                                   np.asarray(st_ref[key_]),
                                   rtol=2e-3, atol=2e-3)


def test_mlstm_chunkwise_nonzero_initial_state():
    """Prefill continuation: carry a warm state across the boundary."""
    b, s, h, hd = 1, 256, 2, 8
    q, k, v, ig, fg = _mk_qkvg(jax.random.key(7), b, 2 * s, h, hd)
    st0 = {"C": jnp.zeros((b, h, hd, hd)), "n": jnp.zeros((b, h, hd)),
           "m": jnp.full((b, h), -1e30)}
    y_all, _ = ssm._mlstm_seq(q, k, v, ig, fg, st0)
    # first half sequential, second half chunkwise from the carried state
    y1, st1 = ssm._mlstm_seq(*[a[:, :s] for a in (q, k, v, ig, fg)], st0)
    y2, _ = ssm._mlstm_chunkwise(*[a[:, s:] for a in (q, k, v, ig, fg)],
                                 st1, chunk=128)
    np.testing.assert_allclose(np.asarray(y2), np.asarray(y_all[:, s:]),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("seed", [0, 3])
def test_slstm_parallel_matches_seq(seed):
    b, s, d = 2, 200, 24
    ks = jax.random.split(jax.random.key(seed), 4)
    z, ig, fg, og = (jax.random.normal(ks[i], (b, s, d), jnp.float32)
                     * (3.0 if i in (1, 2) else 1.0) for i in range(4))
    st = {"c": jnp.zeros((b, d)), "n": jnp.ones((b, d)),
          "m": jnp.zeros((b, d))}
    y_ref, st_ref = ssm._slstm_seq(z, ig, fg, og, st)
    y_par, st_par = ssm._slstm_parallel(z, ig, fg, og, st)
    np.testing.assert_allclose(np.asarray(y_par), np.asarray(y_ref),
                               rtol=2e-4, atol=2e-4)
    for key_ in ("c", "n", "m"):
        np.testing.assert_allclose(np.asarray(st_par[key_]),
                                   np.asarray(st_ref[key_]),
                                   rtol=2e-3, atol=2e-3)


def test_slstm_parallel_warm_state():
    b, s, d = 1, 64, 8
    ks = jax.random.split(jax.random.key(9), 4)
    z, ig, fg, og = (jax.random.normal(ks[i], (b, s, d)) * 2.0
                     for i in range(4))
    st = {"c": jnp.full((b, d), 0.7), "n": jnp.full((b, d), 1.3),
          "m": jnp.full((b, d), 0.4)}
    y_ref, _ = ssm._slstm_seq(z, ig, fg, og, st)
    y_par, _ = ssm._slstm_parallel(z, ig, fg, og, st)
    np.testing.assert_allclose(np.asarray(y_par), np.asarray(y_ref),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("s", [128, 256])
def test_selective_scan_chunked_matches_seq(s):
    b, di, ds = 2, 12, 4
    ks = jax.random.split(jax.random.key(1), 5)
    u = jax.random.normal(ks[0], (b, s, di))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, di)))
    A = -jnp.exp(jax.random.normal(ks[2], (di, ds)) * 0.3)
    B = jax.random.normal(ks[3], (b, s, ds))
    C = jax.random.normal(ks[4], (b, s, ds))
    D = jnp.ones((di,))
    h0 = jnp.zeros((b, di, ds))
    y_ref, h_ref = ssm._selective_scan_seq(u, dt, A, B, C, D, h0)
    y_chk, h_chk = ssm._selective_scan(u, dt, A, B, C, D, h0, chunk=32)
    np.testing.assert_allclose(np.asarray(y_chk), np.asarray(y_ref),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(h_chk), np.asarray(h_ref),
                               rtol=2e-3, atol=2e-3)


def test_mlstm_gradients_flow_through_chunkwise():
    b, s, h, hd = 1, 256, 2, 8
    q, k, v, ig, fg = _mk_qkvg(jax.random.key(4), b, s, h, hd)
    st = {"C": jnp.zeros((b, h, hd, hd)), "n": jnp.zeros((b, h, hd)),
          "m": jnp.full((b, h), -1e30)}

    def loss_chunk(v_):
        y, _ = ssm._mlstm_chunkwise(q, k, v_, ig, fg, st, chunk=128)
        return jnp.sum(y ** 2)

    def loss_seq(v_):
        y, _ = ssm._mlstm_seq(q, k, v_, ig, fg, st)
        return jnp.sum(y ** 2)

    g1 = jax.grad(loss_chunk)(v)
    g2 = jax.grad(loss_seq)(v)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2),
                               rtol=5e-3, atol=5e-3)
