"""Tests for finite fields, triangle block partitions, and diagonal
assignment (paper §VI)."""
import math

import numpy as np
import pytest

pytest.importorskip("hypothesis",
                    reason="property-based tests need the hypothesis "
                           "dev dependency (requirements-dev.txt)")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.gf import GF, get_field, prime_power
from repro.core.lower_bounds import (mem_independent_case,
                                     memory_independent_lower_bound,
                                     sequential_reads_lower_bound)
from repro.core.triangle import (affine_partition, assign_diagonals,
                                 cyclic_partition, optimal_partition,
                                 projective_partition,
                                 refined_cyclic_partition,
                                 steiner_divisibility, trivial_partition,
                                 validate_partition)

PRIME_POWERS = [2, 3, 4, 5, 7, 8, 9, 11, 13]


# ---------------------------------------------------------------------------
# GF(q)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("q", PRIME_POWERS + [16, 25, 27])
def test_gf_field_axioms(q):
    F = get_field(q)
    add, mul = F.add_table, F.mul_table
    # commutativity + identity
    assert (add == add.T).all() and (mul == mul.T).all()
    assert (add[0] == np.arange(q)).all()
    assert (mul[1] == np.arange(q)).all()
    assert (mul[0] == 0).all()
    # every nonzero element invertible
    for a in range(1, q):
        assert (mul[a] == 1).sum() == 1
    # associativity + distributivity on samples
    rng = np.random.default_rng(q)
    for _ in range(20):
        a, b, c = rng.integers(0, q, 3)
        assert add[add[a, b], c] == add[a, add[b, c]]
        assert mul[mul[a, b], c] == mul[a, mul[b, c]]
        assert mul[a, add[b, c]] == add[mul[a, b], mul[a, c]]


def test_prime_power():
    assert prime_power(8) == (2, 3)
    assert prime_power(9) == (3, 2)
    assert prime_power(7) == (7, 1)
    assert prime_power(12) is None
    assert prime_power(1) is None


# ---------------------------------------------------------------------------
# constructions
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("c", [2, 3, 4, 5, 7, 8, 9])
def test_affine_plane(c):
    p = affine_partition(c)
    validate_partition(p.n, p.blocks)
    assert p.n == c * c and p.num_blocks == c * c + c
    assert all(len(R) == c for R in p.blocks)
    # Steiner (c^2, c, 2): each index in (n-1)/(r-1) = c+1 blocks
    counts = np.zeros(p.n, int)
    for R in p.blocks:
        counts[R] += 1
    assert (counts == c + 1).all()


@pytest.mark.parametrize("c", [2, 3, 4, 5])
def test_projective_plane(c):
    p = projective_partition(c)
    validate_partition(p.n, p.blocks)
    assert p.n == c * c + c + 1 == p.num_blocks  # de Bruijn–Erdős minimum
    assert all(len(R) == c + 1 for R in p.blocks)
    # projective planes are the unique balanced minimal clique partitions
    # (paper Thm 13) and every block gets exactly one diagonal
    assert all(len(d) == 1 for d in p.diag)


def test_higher_dimensional_spaces():
    p = affine_partition(3, alpha=3)       # lines of A^3(F_3): Steiner(27,3,2)
    validate_partition(p.n, p.blocks)
    assert p.n == 27 and all(len(R) == 3 for R in p.blocks)
    p = projective_partition(2, alpha=3)   # Steiner(15,3,2) — paper appendix
    validate_partition(p.n, p.blocks)
    assert p.n == 15 and all(len(R) == 3 for R in p.blocks)
    assert p.num_blocks == 35


@pytest.mark.parametrize("c,k", [(5, 4), (7, 5), (5, 5), (11, 4), (7, 3)])
def test_cyclic_family(c, k):
    p = cyclic_partition(c, k)
    validate_partition(p.n, p.blocks)
    assert p.n == c * k


def test_cyclic_invalid():
    with pytest.raises(ValueError):
        cyclic_partition(4, 4)  # gcd(2,4) != 1


@pytest.mark.parametrize("c,k,M,m", [(29, 10, 128, 1), (47, 14, 200, 2)])
def test_refined_cyclic(c, k, M, m):
    p = refined_cyclic_partition(c, k, M, m)
    validate_partition(p.n, p.blocks, n_real=p.n_real)
    assert p.n_real == c * k
    # memory constraint respected by every block
    r_cap = int(math.isqrt(2 * M + m * m)) - m
    for R in p.blocks:
        assert len(R) <= max(r_cap, k)


def test_diagonal_assignment_covers_once():
    for c in [3, 4, 5, 7]:
        p = affine_partition(c)
        ds = [d for dl in p.diag for d in dl]
        assert len(ds) == len(set(ds)) == p.n
        for k, dl in enumerate(p.diag):
            assert len(dl) <= 1           # Steiner system: spread assignment
            for d in dl:
                assert d in p.blocks[k]
    # trivial partition: all diagonals on the single block
    p = trivial_partition(9)
    assert sorted(p.diag[0]) == list(range(9))


def test_intersection_structure():
    # lines meet in <= 1 point — the property the 2D all-to-all routing uses
    p = affine_partition(4)
    t = p.intersection_table()
    assert t.shape == (20, 20)
    # affine plane: each pair of non-parallel lines meets exactly once;
    # among c(c+1) lines, each line is parallel to c-1 others
    for a in range(p.num_blocks):
        misses = sum(1 for b in range(p.num_blocks) if b != a and t[a, b] < 0)
        assert misses == 4 - 1


@settings(max_examples=25, deadline=None)
@given(n1=st.integers(20, 400), logM=st.integers(5, 10),
       m=st.sampled_from([1, 2]))
def test_optimal_partition_always_valid(n1, logM, m):
    M = 1 << logM
    p = optimal_partition(n1, M, m)
    validate_partition(p.n, p.blocks, n_real=min(p.n_real, p.n))
    assert p.n_real >= n1 or p.construction == "trivial"
    # every block fits fast memory: r(r-1)/2 + 1 + m*r <= M (or trivial)
    if p.construction != "trivial":
        for R in p.blocks:
            r = len(R)
            assert r * (r - 1) // 2 + 1 + m * r <= M


def test_steiner_divisibility():
    assert steiner_divisibility(16, 4)       # affine c=4
    assert steiner_divisibility(13, 4)       # projective c=3
    assert steiner_divisibility(15, 3)       # Steiner(15,3,2)
    assert not steiner_divisibility(17, 4)


def test_lower_bound_cases():
    # case boundaries of Theorem 9
    assert mem_independent_case(100, 1000, 4, 1) == 1       # n1<=mn2, small P
    assert mem_independent_case(1000, 10, 4, 1) == 2        # mn2<n1, small P
    assert mem_independent_case(100, 1000, 10**4, 1) == 3   # large P
    b = memory_independent_lower_bound(1000, 10, 4, 1)
    assert b.case == 2 and b.bound > 0
    # sequential bound positive in sane regimes
    assert sequential_reads_lower_bound(512, 64, 128, 1) > 0
